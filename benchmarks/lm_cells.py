"""Beyond-paper: the 40-cell LM roofline summary from the dry-run artifacts
(EXPERIMENTS.md §Roofline reads the same data)."""
from __future__ import annotations

import os

from benchmarks.common import emit
from repro.launch import roofline


def run(indir="experiments/dryrun_opt"):
    if not os.path.isdir(indir) or not os.listdir(indir):
        fallback = "experiments/dryrun"
        if os.path.isdir(fallback) and os.listdir(fallback):
            indir = fallback
        else:
            print(f"(no dry-run artifacts under {indir} — run "
                  "`python -m repro.launch.dryrun --all` first)\n")
            return []
    rows = []
    for r in roofline.load(indir):
        if r.get("status") == "n/a":
            rows.append((r["arch"], r["shape"], "-", "-", "-", "n/a", "-"))
            continue
        rows.append((r["arch"], r["shape"], f"{r['compute_s']:.3e}",
                     f"{r['memory_s']:.3e}", f"{r['collective_s']:.3e}",
                     r["dominant"], f"{r['roofline_fraction']:.4f}"))
    emit(rows, ["arch", "shape", "compute_s", "memory_s", "collective_s",
                "dominant", "roofline_fraction"])
    return rows


if __name__ == "__main__":
    run()
