"""Shared benchmark plumbing: plan-level latency from the FPGA cycle model
(paper §IV-A formulas — reproduces the paper's tables), CSV emit, and the
machine-readable ``BENCH_*.json`` perf records CI uploads so the perf
trajectory is tracked across PRs."""
from __future__ import annotations

import json
import pathlib
import platform

from repro import gcv, obs
from repro.core import CompileOptions, compile_graph
from repro.core.perf_model import FPGA


def plan_latency_s(plan, model=FPGA) -> float:
    """Batch-size-one latency under the paper's execution model: ops run
    layer-by-layer, each op's compute is balanced over the 8 PEs and
    overlapped with its memory traffic (max(compute, mem))."""
    return sum(model.op_seconds(op.cycles, op.bytes_moved)
               for op in plan.ops)


def portion_latency_s(plan, model=FPGA) -> dict:
    out: dict[str, float] = {}
    for op in plan.ops:
        out[op.portion] = out.get(op.portion, 0.0) \
            + model.op_seconds(op.cycles, op.bytes_moved)
    return out


def compile_task(graph, **opts):
    return compile_graph(graph, CompileOptions(**opts))


def measure_wall_ms(plan, iters: int = 3, kernels: str = "auto") -> float:
    """CPU wall-clock of the jit'd executor (sanity only — the modelled
    latency is the paper-comparable number).  ``kernels`` picks the per-op
    realization mode (auto/xla/pallas/measured)."""
    model = gcv.compile(plan, options=CompileOptions(kernels=kernels))
    ins = model.random_inputs()
    out = model.run(**ins)                   # compile + warm
    t0 = obs.now()
    for _ in range(iters):
        out = model.run(**ins)
    _ = [o for o in (out if isinstance(out, (list, tuple)) else [out])]
    return (obs.now() - t0) / iters * 1e3


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()


def percentile_ms(latencies_s, q) -> float:
    """q-th percentile of a list of second-valued latencies, in ms."""
    if not latencies_s:
        return float("nan")
    xs = sorted(latencies_s)
    idx = min(len(xs) - 1, max(0, round(q / 100 * (len(xs) - 1))))
    return xs[idx] * 1e3


def write_bench_json(name: str, payload: dict) -> pathlib.Path:
    """Write one machine-readable perf record (``BENCH_<name>.json``).

    The file lands in the current working directory (CI runs from the repo
    root and uploads ``BENCH_*.json`` as artifacts).  Host metadata is
    attached so numbers from different machines are never compared blind —
    including the jax backend and device kind, which dominate wall-clock
    numbers far more than the CPU model does.
    """
    import jax
    path = pathlib.Path(f"BENCH_{name}.json")
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:            # no devices visible (headless CI quirk)
        device_kind = None
    record = {"bench": name,
              "host": {"machine": platform.machine(),
                       "python": platform.python_version(),
                       "system": platform.system(),
                       "jax": jax.__version__,
                       "backend": jax.default_backend(),
                       "device_kind": device_kind},
              **payload}
    path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}")
    return path
