"""Shared benchmark plumbing: plan-level latency from the FPGA cycle model
(paper §IV-A formulas — reproduces the paper's tables) and CSV emit."""
from __future__ import annotations

import time

from repro.core import CompileOptions, compile_graph
from repro.core.executor import build_runner, random_inputs
from repro.core.perf_model import FPGA


def plan_latency_s(plan, model=FPGA) -> float:
    """Batch-size-one latency under the paper's execution model: ops run
    layer-by-layer, each op's compute is balanced over the 8 PEs and
    overlapped with its memory traffic (max(compute, mem))."""
    return sum(model.op_seconds(op.cycles, op.bytes_moved)
               for op in plan.ops)


def portion_latency_s(plan, model=FPGA) -> dict:
    out: dict[str, float] = {}
    for op in plan.ops:
        out[op.portion] = out.get(op.portion, 0.0) \
            + model.op_seconds(op.cycles, op.bytes_moved)
    return out


def compile_task(graph, **opts):
    return compile_graph(graph, CompileOptions(**opts))


def measure_wall_ms(plan, iters: int = 3, use_pallas: bool = False) -> float:
    """CPU wall-clock of the jit'd executor (sanity only — the modelled
    latency is the paper-comparable number)."""
    run = build_runner(plan, use_pallas=use_pallas)
    ins = random_inputs(plan)
    out = run(**ins)                         # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = run(**ins)
    _ = [o for o in (out if isinstance(out, (list, tuple)) else [out])]
    return (time.perf_counter() - t0) / iters * 1e3


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()
