"""Paper Table VIII / XI analogue: standalone CNNs c1–c5. Modelled
GCV-Turbo throughput vs the paper's reported images/second."""
from __future__ import annotations

from benchmarks.common import compile_task, emit, plan_latency_s
from repro.gnncv import cnn_zoo

PAPER_THROUGHPUT = {"c1_alexnet": 512.9, "c2_resnet50": 58.8,
                    "c3_resnet101": 46.5, "c4_vgg16": 254.7,
                    "c5_vgg19": 127.3}


def build_all():
    return {
        "c1_alexnet": cnn_zoo.alexnet(),
        "c2_resnet50": cnn_zoo.resnet(50),
        "c3_resnet101": cnn_zoo.resnet(101),
        "c4_vgg16": cnn_zoo.vgg(16),
        "c5_vgg19": cnn_zoo.vgg(19),
    }


def run():
    rows = []
    for name, g in build_all().items():
        plan = compile_task(g, target="fpga")
        lat = plan_latency_s(plan)
        thr = 1.0 / lat
        paper = PAPER_THROUGHPUT[name]
        rows.append((name, f"{lat*1e3:.3f}", f"{thr:.1f}", f"{paper:.1f}",
                     f"{thr/paper:.2f}"))
    emit(rows, ["model", "modelled_latency_ms", "modelled_img_per_s",
                "paper_img_per_s", "ratio_model/paper"])
    return rows


if __name__ == "__main__":
    run()
