"""Paper Fig. 2 / Fig. 10 / Table VII analogue: CNN vs GNN vs layout-
transformation share of each GNN-CV task, before/after DM fusion.

GCV-Turbo's claim (Table VII): the DM/layout overhead is fully eliminated
('∞' speedup). Here: dm share with dm_fusion=False vs True."""
from __future__ import annotations

from benchmarks.common import compile_task, emit, portion_latency_s
from benchmarks.table2_tasks import build_all


def run():
    rows = []
    for name, g in build_all().items():
        base = compile_task(g, target="fpga", dm_fusion=False)
        opt = compile_task(g, target="fpga", dm_fusion=True)
        pb = portion_latency_s(base)
        po = portion_latency_s(opt)
        tot_b = sum(pb.values()) or 1.0
        tot_o = sum(po.values()) or 1.0
        rows.append((
            name,
            f"{pb.get('cnn', 0) / tot_b:.3f}",
            f"{pb.get('gnn', 0) / tot_b:.3f}",
            f"{pb.get('dm', 0) / tot_b:.3f}",
            f"{po.get('dm', 0) / tot_o:.3f}",
        ))
    emit(rows, ["task", "cnn_share", "gnn_share", "dm_share_unfused",
                "dm_share_fused(paper:0)"])
    return rows


if __name__ == "__main__":
    run()
