"""Paper §VII-C layer-fusion ablation: speedup from Step-1 fusion across
b1–b6. Paper reports 11.8%–48.9%."""
from __future__ import annotations

from benchmarks.common import compile_task, emit, plan_latency_s
from benchmarks.table2_tasks import build_all


def run():
    rows = []
    for name, g in build_all().items():
        off = plan_latency_s(compile_task(g, target="fpga", fuse=False))
        on = plan_latency_s(compile_task(g, target="fpga", fuse=True))
        speedup = (off - on) / on * 100.0
        rows.append((name, f"{off*1e3:.3f}", f"{on*1e3:.3f}",
                     f"{speedup:.1f}%", "11.8%-48.9%"))
    emit(rows, ["task", "no_fusion_ms", "fusion_ms", "speedup",
                "paper_range"])
    return rows


if __name__ == "__main__":
    run()
