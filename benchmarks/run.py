"""Benchmark driver — one section per paper table/figure plus the LM-cell
roofline summary. ``PYTHONPATH=src python -m benchmarks.run``"""
from __future__ import annotations

import sys
import time

SECTIONS = [
    ("Table II / Fig.9 — GNN-CV tasks b1-b6 (modelled latency)",
     "benchmarks.table2_tasks"),
    ("Fig.2 / Fig.10 / Table VII — portion breakdown + DM elimination",
     "benchmarks.fig2_breakdown"),
    ("Table VIII / XI — standalone CNNs c1-c5",
     "benchmarks.table8_cnns"),
    ("Table IX / XII — standalone GNNs g1-g3",
     "benchmarks.table9_gnns"),
    ("§VII-C — layer-fusion ablation", "benchmarks.ablation_fusion"),
    ("§VII-C — sparsity-aware-mapping ablation",
     "benchmarks.ablation_sparsity"),
    ("Beyond-paper — 40-cell LM roofline (from dry-run artifacts)",
     "benchmarks.lm_cells"),
    ("Beyond-paper — micro-batched GNN-CV serving throughput + liveness "
     "memory planning", "benchmarks.serve_gnncv"),
]


def main() -> None:
    import importlib
    t00 = time.time()
    failures = 0
    for title, mod_name in SECTIONS:
        print(f"==== {title} ====")
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception as e:                       # noqa: BLE001
            failures += 1
            print(f"FAILED: {type(e).__name__}: {e}\n")
        print(f"[{time.time()-t0:.1f}s]\n")
    print(f"benchmarks done in {time.time()-t00:.1f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
